"""Quantized ResNet-18/50/152 — the paper's own benchmark CNNs.

Convolutions execute as im2col + the mixed-precision matmul (the paper's
PE array processes CONV layers as GEMMs; Section III: "we focus on the
processing of CONV layers").  First conv and the FC classifier are
boundary layers (8 bit); every inner conv runs at w_Q.

Identity-shortcut handling follows the paper's "identity-shortcut-
connection mixed-precision CNNs": shortcuts stay in the activation
domain (8 bit), projection shortcuts are quantized convs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import plan as plan_lib
from repro.core.dse import Gemm
from repro.core.precision import PrecisionPolicy
from repro.nn import layers as nnl
from repro.nn import quantized as Q
from repro.nn.param import ParamSpec

__all__ = ["ResNetConfig", "RESNET_STAGES", "specs", "forward",
           "gemm_workload", "model_flops", "init_bn_state",
           "pack_for_serve", "serve_forward", "layer_param_counts",
           "layer_classes", "layer_weights", "inner_layer_names",
           "plan_layer_names"]

# Block param keys -> gemm_workload name suffixes: plan layer names are
# the workload names ("s0b0c1", "s0b0p", ...), the same ids the DSE
# scores, so one vocabulary covers cost model, plan JSON, and pack/serve.
_PLAN_SUFFIX = {"conv1": "c1", "conv2": "c2", "conv3": "c3", "proj": "p"}

RESNET_STAGES = {
    18: ("basic", (2, 2, 2, 2)),
    50: ("bottleneck", (3, 4, 6, 3)),
    152: ("bottleneck", (3, 8, 36, 3)),
}


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    depth: int
    n_classes: int = 1000
    img_size: int = 224
    width: int = 64
    family: str = "cnn"
    # Truncated-depth variants (CI smoke benches): overrides the
    # per-depth stage table, e.g. (1, 1) = a 2-block net.
    stages_override: Optional[Tuple[int, ...]] = None

    @property
    def block(self) -> str:
        return RESNET_STAGES[self.depth][0]

    @property
    def stages(self) -> Tuple[int, ...]:
        return self.stages_override or RESNET_STAGES[self.depth][1]

    @property
    def fc_in(self) -> int:
        """Channels entering the classifier: last stage width x expansion."""
        expansion = 4 if self.block == "bottleneck" else 1
        return self.width * 2 ** (len(self.stages) - 1) * expansion


# --- im2col conv ------------------------------------------------------------
# The conv-as-GEMM machinery lives in nn/quantized (shared with any CNN);
# re-exported here for backwards compatibility.

im2col = Q.im2col
qconv_spec = Q.qconv_spec
qconv_apply = Q.qconv_apply


# --- batch norm -------------------------------------------------------------


def bn_spec(c: int) -> Dict:
    return {
        "scale": ParamSpec(shape=(c,), axes=("act_embed",), init="ones"),
        "bias": ParamSpec(shape=(c,), axes=("act_embed",), init="zeros"),
    }


def init_bn_state(specs_tree):
    """Running-stats state tree parallel to every bn param subtree."""
    out = {}
    for k, v in specs_tree.items():
        if isinstance(v, dict):
            if "scale" in v and "bias" in v and len(v) == 2:
                c = v["scale"].shape[0]
                out[k] = {"mean": jnp.zeros((c,)), "var": jnp.ones((c,))}
            else:
                sub = init_bn_state(v)
                if sub:
                    out[k] = sub
    return out


def bn_apply(p, state, x, *, training: bool, momentum: float = 0.9):
    xf = x.astype(jnp.float32)
    if training:
        mean = jnp.mean(xf, axis=(0, 1, 2))
        var = jnp.var(xf, axis=(0, 1, 2))
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    y = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype), new_state


# --- blocks -----------------------------------------------------------------


def _cw(policy, name: str) -> bool:
    """Per-layer channel-wise flag via the shared resolver: channel-wise
    layers carry a per-output-channel gw; per-tensor layers a scalar."""
    return plan_lib.resolve_policy(policy, name).channel_wise


def _qc(cin, cout, k, policy, name, layer_class="inner"):
    """One conv spec, its workload name riding in the marker (the shared
    funnel resolves the identical per-layer format at pack/serve time)."""
    return qconv_spec(cin, cout, k, layer_class=layer_class, name=name,
                      channel_wise=_cw(policy, name))


def _basic_spec(cin, cout, stride, policy, lname):
    s = {
        "conv1": _qc(cin, cout, 3, policy, lname + "c1"),
        "bn1": bn_spec(cout),
        "conv2": _qc(cout, cout, 3, policy, lname + "c2"),
        "bn2": bn_spec(cout),
    }
    if stride != 1 or cin != cout:
        s["proj"] = _qc(cin, cout, 1, policy, lname + "p")
        s["bn_proj"] = bn_spec(cout)
    return s


def _bottleneck_spec(cin, cmid, stride, policy, lname):
    cout = 4 * cmid
    s = {
        "conv1": _qc(cin, cmid, 1, policy, lname + "c1"),
        "bn1": bn_spec(cmid),
        "conv2": _qc(cmid, cmid, 3, policy, lname + "c2"),
        "bn2": bn_spec(cmid),
        "conv3": _qc(cmid, cout, 1, policy, lname + "c3"),
        "bn3": bn_spec(cout),
    }
    if stride != 1 or cin != cout:
        s["proj"] = _qc(cin, cout, 1, policy, lname + "p")
        s["bn_proj"] = bn_spec(cout)
    return s


def _block_channels(cfg: ResNetConfig):
    """Yield (stage, block, cin, cmid/cout, stride)."""
    expansion = 4 if cfg.block == "bottleneck" else 1
    cin = cfg.width
    for si, n_blocks in enumerate(cfg.stages):
        cmid = cfg.width * (2 ** si)
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            yield si, bi, cin, cmid, stride
            cin = cmid * expansion


def specs(cfg: ResNetConfig, mode: str = "train",
          policy: PrecisionPolicy = PrecisionPolicy()) -> Dict:
    del mode  # resnet serves via the same QAT tree (packed offline)
    tree: Dict = {
        "stem": _qc(3, cfg.width, 7, policy, "stem", layer_class="boundary"),
        "bn_stem": bn_spec(cfg.width),
        "fc": Q.qlinear_spec(cfg.fc_in, cfg.n_classes,
                             axes=("embed", "vocab"),
                             layer_class="boundary", name="fc",
                             channel_wise=_cw(policy, "fc")),
    }
    mk = _bottleneck_spec if cfg.block == "bottleneck" else _basic_spec
    for si, bi, cin, cmid, stride in _block_channels(cfg):
        key = f"s{si}b{bi}"
        tree[key] = mk(cin, cmid, stride, policy, key)
    return tree


def _basic_fwd(p, st, x, policy, stride, training, lname=""):
    h = qconv_apply(p["conv1"], x, policy, k=3, stride=stride,
                    name=lname + "c1")
    h, st1 = bn_apply(p["bn1"], st["bn1"], h, training=training)
    h = jax.nn.relu(h)
    h = qconv_apply(p["conv2"], h, policy, k=3, name=lname + "c2")
    h, st2 = bn_apply(p["bn2"], st["bn2"], h, training=training)
    new_st = {"bn1": st1, "bn2": st2}
    if "proj" in p:
        x = qconv_apply(p["proj"], x, policy, k=1, stride=stride,
                        name=lname + "p")
        x, stp = bn_apply(p["bn_proj"], st["bn_proj"], x, training=training)
        new_st["bn_proj"] = stp
    return jax.nn.relu(x + h), new_st


def _bottleneck_fwd(p, st, x, policy, stride, training, lname=""):
    h = qconv_apply(p["conv1"], x, policy, k=1, name=lname + "c1")
    h, st1 = bn_apply(p["bn1"], st["bn1"], h, training=training)
    h = jax.nn.relu(h)
    h = qconv_apply(p["conv2"], h, policy, k=3, stride=stride,
                    name=lname + "c2")
    h, st2 = bn_apply(p["bn2"], st["bn2"], h, training=training)
    h = jax.nn.relu(h)
    h = qconv_apply(p["conv3"], h, policy, k=1, name=lname + "c3")
    h, st3 = bn_apply(p["bn3"], st["bn3"], h, training=training)
    new_st = {"bn1": st1, "bn2": st2, "bn3": st3}
    if "proj" in p:
        x = qconv_apply(p["proj"], x, policy, k=1, stride=stride,
                        name=lname + "p")
        x, stp = bn_apply(p["bn_proj"], st["bn_proj"], x, training=training)
        new_st["bn_proj"] = stp
    return jax.nn.relu(x + h), new_st


def apply_with_state(cfg: ResNetConfig, params, state, images, policy,
                     *, training: bool = False):
    """images (B,H,W,3) -> (logits (B,classes), new bn state)."""
    x = qconv_apply(params["stem"], images, policy, k=7, stride=2,
                    layer_class="boundary", quantize_act=False, name="stem")
    x, st_stem = bn_apply(params["bn_stem"], state["bn_stem"], x,
                          training=training)
    x = jax.nn.relu(x)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    new_state = {"bn_stem": st_stem}
    fwd = _bottleneck_fwd if cfg.block == "bottleneck" else _basic_fwd
    for si, bi, cin, cmid, stride in _block_channels(cfg):
        key = f"s{si}b{bi}"
        x, st = fwd(params[key], state[key], x, policy, stride, training,
                    lname=key)
        new_state[key] = st
    x = jnp.mean(x, axis=(1, 2))
    logits = Q.qlinear_apply(
        {k: v for k, v in params["fc"].items() if k != Q.QMARK}, x,
        policy, layer_class="boundary", name="fc")
    return logits, new_state


def forward(cfg: ResNetConfig, params, images, policy, *, mode="train",
            impl="xla", state=None):
    """ModelAPI-compatible facade: logits only.  BN running stats are
    threaded by the CNN train driver via ``apply_with_state``; a fresh
    state (zeros/ones) is used when none is supplied (smoke tests, PTQ
    evaluation of freshly initialized nets)."""
    del impl
    if state is None:
        state = init_bn_state(specs(cfg))
    logits, _ = apply_with_state(cfg, params, state, images, policy,
                                 training=(mode == "train"))
    return logits


# --- packed serve path (fused epilogues) ------------------------------------


def _fold_bn(bn_params, bn_state, eps: float = 1e-5):
    """Inference BN -> (scale, shift) f32 (1, C) for the kernel epilogue.

    y = (x - mean) * rsqrt(var + eps) * g + b  ==  x * scale + shift
    """
    g = jnp.asarray(bn_params["scale"], jnp.float32)
    b = jnp.asarray(bn_params["bias"], jnp.float32)
    mean = jnp.asarray(bn_state["mean"], jnp.float32)
    var = jnp.asarray(bn_state["var"], jnp.float32)
    s = g * jax.lax.rsqrt(var + eps)
    t = b - mean * s
    c = s.shape[-1]
    return s.reshape(1, c), t.reshape(1, c)


def pack_for_serve(cfg: ResNetConfig, params, state, policy):
    """Trained QAT tree + BN running stats -> deployed serve tree.

    Every qconv/qlinear subtree becomes packed digit planes through the
    SHARED plan-aware funnel (``Q.pack_tree`` — the spec markers carry
    each layer's workload name, so a ``PrecisionPlan`` packs every layer
    at its own (w_bits, k, channel_wise): plane count, packed-K bytes
    and gamma layout all vary per layer).  Every BatchNorm is folded
    into the (scale, shift) pair its following matmul applies in the
    fused kernel epilogue — after this, the serve graph contains no
    standalone BN op at all.  ``serve_forward`` resolves the identical
    per-layer formats, so the packed tree and the serve graph agree.
    """
    if isinstance(policy, plan_lib.PrecisionPlan):
        policy.validate_layers(plan_layer_names(cfg))
    sp = specs(cfg, policy=policy)
    packed = Q.pack_tree(params, sp, policy)
    out = {}
    for key, sub in packed.items():
        if key.startswith("bn"):
            out[key] = _fold_bn(params[key], state[key])
        elif Q.is_qlinear(sp[key]):
            out[key] = sub
        else:  # residual block: fold its BNs, keep the packed convs
            out[key] = {n: (_fold_bn(params[key][n], state[key][n])
                            if n.startswith("bn") else v)
                        for n, v in sub.items()}
    return out


def _shortcut(p, x, policy, stride, impl, tile, dataflow, lname=""):
    """Identity or projection shortcut (projection: conv + folded BN)."""
    if "proj" not in p:
        return x
    s, t = p["bn_proj"]
    return Q.qconv_serve_apply(
        p["proj"], x, policy, k=1, stride=stride, impl=impl, tile=tile,
        epilogue=Q.EpilogueSpec(bn=True), scale=s, shift=t,
        dataflow=dataflow, name=lname + "p")


def _basic_serve(p, x, policy, stride, impl, tile, dataflow, lname=""):
    sc = _shortcut(p, x, policy, stride, impl, tile, dataflow, lname)
    s1, t1 = p["bn1"]
    h = Q.qconv_serve_apply(
        p["conv1"], x, policy, k=3, stride=stride, impl=impl,
        tile=tile, epilogue=Q.EpilogueSpec(bn=True, relu=True), scale=s1,
        shift=t1, dataflow=dataflow, name=lname + "c1")
    s2, t2 = p["bn2"]
    # conv2 carries BN2 + shortcut add + final ReLU in one kernel epilogue.
    return Q.qconv_serve_apply(
        p["conv2"], h, policy, k=3, impl=impl, tile=tile,
        epilogue=Q.EpilogueSpec(bn=True, residual=True, relu=True),
        scale=s2, shift=t2, residual=sc, dataflow=dataflow,
        name=lname + "c2")


def _bottleneck_serve(p, x, policy, stride, impl, tile, dataflow, lname=""):
    sc = _shortcut(p, x, policy, stride, impl, tile, dataflow, lname)
    s1, t1 = p["bn1"]
    h = Q.qconv_serve_apply(
        p["conv1"], x, policy, k=1, impl=impl, tile=tile,
        epilogue=Q.EpilogueSpec(bn=True, relu=True), scale=s1, shift=t1,
        dataflow=dataflow, name=lname + "c1")
    s2, t2 = p["bn2"]
    h = Q.qconv_serve_apply(
        p["conv2"], h, policy, k=3, stride=stride, impl=impl,
        tile=tile, epilogue=Q.EpilogueSpec(bn=True, relu=True), scale=s2,
        shift=t2, dataflow=dataflow, name=lname + "c2")
    s3, t3 = p["bn3"]
    return Q.qconv_serve_apply(
        p["conv3"], h, policy, k=1, impl=impl, tile=tile,
        epilogue=Q.EpilogueSpec(bn=True, residual=True, relu=True),
        scale=s3, shift=t3, residual=sc, dataflow=dataflow,
        name=lname + "c3")


def serve_forward(cfg: ResNetConfig, packed, images, policy, *,
                  impl: str = "auto", tile=None, dataflow: str = "auto"):
    """Deployed forward over a ``pack_for_serve`` tree.

    Every inner block runs BN + ReLU + shortcut through the fused mpmm
    epilogue (no standalone BN op in the traced graph); with
    ``tile=None`` each layer's pallas tile comes from the DSE autotuner,
    and with ``dataflow='auto'`` (the default) each conv picks im2col vs
    implicit-GEMM through the DSE patch-reuse model — on the implicit
    path the network serves without ever materializing a patch matrix.
    ``dataflow='im2col'`` pins the old materialized path (benchmarks
    use it as the baseline).

    ``policy`` may also be a ``PrecisionPlan``: every layer resolves its
    own (w_bits, k, channel_wise, dataflow) through the shared funnel
    inside ``Q.qconv_serve_apply`` — matching the per-layer formats
    ``pack_for_serve`` packed — while an explicit non-'auto'
    ``dataflow`` argument still pins every conv globally (benchmarks).
    """
    s, t = packed["bn_stem"]
    # The stem sees raw (possibly mean-normalized) pixels that straddle
    # zero; QAT ran it with unquantized activations, so serve uses
    # symmetric signed codes (act_zero=0) — unsigned Eq. 5 codes would
    # clamp every negative input away.
    x = Q.qconv_serve_apply(
        packed["stem"], images, policy, k=7, stride=2,
        layer_class="boundary", impl=impl, tile=tile, act_signed=True,
        epilogue=Q.EpilogueSpec(bn=True, relu=True), scale=s, shift=t,
        dataflow=dataflow, name="stem")
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    fwd = _bottleneck_serve if cfg.block == "bottleneck" else _basic_serve
    for si, bi, cin, cmid, stride in _block_channels(cfg):
        key = f"s{si}b{bi}"
        x = fwd(packed[key], x, policy, stride, impl, tile, dataflow,
                lname=key)
    x = jnp.mean(x, axis=(1, 2))
    return Q.qlinear_serve_apply(packed["fc"], x, policy,
                                 layer_class="boundary", impl=impl,
                                 tile=tile, name="fc")


def gemm_workload(cfg: ResNetConfig, batch: int = 1) -> List[Gemm]:
    """CONV layers as GEMMs at the config's image size (DSE input)."""
    hw = cfg.img_size // 2  # stem stride 2
    gemms = [Gemm("stem", batch * hw * hw, 3 * 49, cfg.width,
                  layer_class="boundary")]
    hw = hw // 2  # maxpool
    expansion = 4 if cfg.block == "bottleneck" else 1
    for si, bi, cin, cmid, stride in _block_channels(cfg):
        hw_out = hw // stride if stride > 1 else hw
        m = batch * hw_out * hw_out
        if cfg.block == "bottleneck":
            gemms += [
                Gemm(f"s{si}b{bi}c1", batch * hw * hw, cin, cmid),
                Gemm(f"s{si}b{bi}c2", m, 9 * cmid, cmid),
                Gemm(f"s{si}b{bi}c3", m, cmid, 4 * cmid),
            ]
            if stride != 1 or cin != 4 * cmid:
                gemms.append(Gemm(f"s{si}b{bi}p", m, cin, 4 * cmid))
        else:
            gemms += [
                Gemm(f"s{si}b{bi}c1", m, 9 * cin, cmid),
                Gemm(f"s{si}b{bi}c2", m, 9 * cmid, cmid),
            ]
            if stride != 1 or cin != cmid:
                gemms.append(Gemm(f"s{si}b{bi}p", m, cin, cmid))
        hw = hw_out
    gemms.append(Gemm("fc", batch, cfg.fc_in, cfg.n_classes,
                      layer_class="boundary"))
    return gemms


def param_counts(cfg: ResNetConfig) -> Dict[str, int]:
    inner = bound = 0
    for g in gemm_workload(cfg, batch=1):
        n = g.k * g.n
        if g.layer_class == "boundary":
            bound += n
        else:
            inner += n
    return {"inner": inner, "boundary": bound}


def layer_param_counts(cfg: ResNetConfig) -> Dict[str, int]:
    """{workload layer name: weight count} — the planner's footprint input."""
    return {g.name: g.k * g.n for g in gemm_workload(cfg, batch=1)}


def layer_classes(cfg: ResNetConfig) -> Dict[str, str]:
    return {g.name: g.layer_class for g in gemm_workload(cfg, batch=1)}


def inner_layer_names(cfg: ResNetConfig) -> List[str]:
    return [g.name for g in gemm_workload(cfg, batch=1)
            if g.layer_class != "boundary"]


def plan_layer_names(cfg: ResNetConfig) -> List[str]:
    """The plan namespace: resnet layers are all named per-instance, so
    the workload names ARE the full namespace (no scoped forms)."""
    return [g.name for g in gemm_workload(cfg, batch=1)]


def layer_weights(cfg: ResNetConfig, params) -> Dict[str, jax.Array]:
    """{workload layer name: FP weight matrix} from a QAT param tree —
    the planner's PTQ-sensitivity input."""
    out = {"stem": params["stem"]["w"], "fc": params["fc"]["w"]}
    for si, bi, cin, cmid, stride in _block_channels(cfg):
        key = f"s{si}b{bi}"
        for pkey, sfx in _PLAN_SUFFIX.items():
            if pkey in params[key]:
                out[key + sfx] = params[key][pkey]["w"]
    return out


def model_flops(cfg: ResNetConfig, *, batch: int = None, tokens: int = None,
                step: str = "train") -> float:
    b = batch if batch is not None else (tokens or 1)
    macs = sum(g.macs for g in gemm_workload(cfg, b))
    return (6.0 if step == "train" else 2.0) * macs


def total_params(cfg: ResNetConfig) -> int:
    c = param_counts(cfg)
    return c["inner"] + c["boundary"]


def active_params(cfg: ResNetConfig) -> int:
    return total_params(cfg)  # dense CNN: all params active
