"""Mamba-2 LM (SSD): attention-free, constant-state decode.

Runs all four shapes including long_500k — the recurrent state is
(B, H, N, P) regardless of context length (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.dse import Gemm
from repro.core.precision import PrecisionPolicy
from repro.nn import layers as nnl
from repro.nn import quantized as Q
from repro.nn import ssm as nnssm
from repro.nn.param import ParamSpec
from repro.nn.partitioning import constrain
from repro.nn.ssm import SSMConfig

__all__ = ["Mamba2Config", "specs", "forward", "prefill", "decode_step",
           "cache_specs", "gemm_workload", "model_flops"]


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    ssm: SSMConfig
    scan_layers: bool = True
    scan_unroll: bool = False
    remat: bool = True
    family: str = "ssm"


def _stack(spec, lead, lead_axes):
    return {k: (ParamSpec(shape=lead + v.shape, dtype=v.dtype,
                          axes=lead_axes + v.axes, init=v.init, const=v.const)
                if isinstance(v, ParamSpec) else _stack(v, lead, lead_axes))
            for k, v in spec.items()}


def specs(cfg: Mamba2Config, mode: str = "train",
          policy: PrecisionPolicy = PrecisionPolicy()) -> Dict:
    serve = mode == "serve"
    lead = (cfg.n_layers,) if cfg.scan_layers else ()
    lead_axes = ("layers",) if cfg.scan_layers else ()
    return {
        "embed": (nnl.embed_serve_spec(nnl.pad_vocab(cfg.vocab), cfg.d_model, policy)
                  if serve else nnl.embed_spec(nnl.pad_vocab(cfg.vocab), cfg.d_model)),
        "final_norm": nnl.rmsnorm_spec(cfg.d_model),
        "head": (Q.qlinear_serve_spec(cfg.d_model, nnl.pad_vocab(cfg.vocab),
                                      axes=("embed", "vocab"),
                                      layer_class="boundary", policy=policy,
                                      name="head")
                 if serve else
                 Q.qlinear_spec(cfg.d_model, nnl.pad_vocab(cfg.vocab), axes=("embed", "vocab"),
                                layer_class="boundary", name="head")),
        "layers": {
            "ln": _stack(nnl.rmsnorm_spec(cfg.d_model), lead, lead_axes),
            "ssm": nnssm.ssm_spec(cfg.ssm, lead=lead, lead_axes=lead_axes,
                                  serve=serve, policy=policy),
        },
    }


def _run(cfg, params, x, policy, *, serve, impl, collect_state):
    def body(carry, lp):
        h = nnl.rmsnorm_apply(lp["ln"], carry)
        o, st = nnssm.ssd_forward(lp["ssm"], h, policy, cfg.ssm,
                                  serve=serve, impl=impl)
        y = constrain(carry + o, ("batch", "seq", "act_embed"))
        return y, st if collect_state else None

    fn = jax.checkpoint(body) if cfg.remat else body
    return jax.lax.scan(fn, x, params["layers"],
                        unroll=True if cfg.scan_unroll else 1)


def _head(cfg, params, x, policy, serve, impl):
    x = nnl.rmsnorm_apply(params["final_norm"], x)
    if serve:
        logits = Q.qlinear_serve_apply(params["head"], x, policy,
                                       layer_class="boundary", impl=impl,
                                       name="head")
    else:
        logits = Q.qlinear_apply(params["head"], x, policy,
                                 layer_class="boundary", name="head")
    return logits[..., :cfg.vocab]  # drop TP vocab padding


def _pad_to_chunk(x, chunk):
    s = x.shape[1]
    pad = (-s) % chunk
    return (jnp.pad(x, ((0, 0), (0, pad), (0, 0))), s) if pad else (x, s)


def forward(cfg, params, tokens, policy, *, mode="train", impl="xla"):
    serve = mode == "serve"
    x = (nnl.embed_serve_apply if serve else nnl.embed_apply)(
        params["embed"], tokens)
    x, s = _pad_to_chunk(x, cfg.ssm.chunk)
    x, _ = _run(cfg, params, x, policy, serve=serve, impl=impl,
                collect_state=False)
    return _head(cfg, params, x[:, :s], policy, serve, impl)


def prefill(cfg, params, tokens, policy, *, impl="xla", mode="serve"):
    serve = mode == "serve"
    x = (nnl.embed_serve_apply if serve else nnl.embed_apply)(
        params["embed"], tokens)
    x, s = _pad_to_chunk(x, cfg.ssm.chunk)
    x, states = _run(cfg, params, x, policy, serve=serve, impl=impl,
                     collect_state=True)
    logits = _head(cfg, params, x[:, s - 1: s], policy, serve, impl)
    return logits[:, 0, :], states


def cache_specs(cfg: Mamba2Config, batch: int, max_len: int):
    one = nnssm.ssm_state_spec(cfg.ssm, batch)
    return {k: jax.ShapeDtypeStruct((cfg.n_layers,) + v.shape, v.dtype)
            for k, v in one.items()}


def cache_axes(cfg: Mamba2Config):
    return {"ssm": ("layers", "batch", "heads", "state", None),
            "conv": ("layers", "batch", None, "mlp")}


def decode_step(cfg, params, cache, tokens, length, policy, *,
                impl="xla", mode="serve"):
    serve = mode == "serve"
    x = (nnl.embed_serve_apply if serve else nnl.embed_apply)(
        params["embed"], tokens)

    def body(carry, xs):
        lp, st = xs
        h = nnl.rmsnorm_apply(lp["ln"], carry)
        o, st = nnssm.ssd_decode_step(lp["ssm"], h, st, policy, cfg.ssm,
                                      serve=serve, impl=impl)
        return carry + o, st

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache),
                                unroll=True if cfg.scan_unroll else 1)
    logits = _head(cfg, params, x, policy, serve, impl)
    return logits[:, 0, :], new_cache


def gemm_workload(cfg: Mamba2Config, tokens: int):
    s = cfg.ssm
    d, di = cfg.d_model, s.d_inner
    gn = s.n_groups * s.d_state
    per = [
        Gemm("in_xbc", tokens, d, di + 2 * gn),
        Gemm("in_z", tokens, d, di),
        Gemm("in_dt", tokens, d, s.n_heads),
        Gemm("out", tokens, di, d),
    ]
    out = [dataclasses.replace(g, count=cfg.n_layers) for g in per]
    out.append(Gemm("head", tokens, d, cfg.vocab, layer_class="boundary"))
    return out


def active_params(cfg: Mamba2Config) -> int:
    s = cfg.ssm
    per = (cfg.d_model * (s.d_inner + 2 * s.n_groups * s.d_state)
           + cfg.d_model * s.d_inner + cfg.d_model * s.n_heads
           + s.d_inner * cfg.d_model)
    return per * cfg.n_layers + 2 * cfg.vocab * cfg.d_model


total_params = active_params


def model_flops(cfg, *, tokens: int, step: str) -> float:
    mult = 6.0 if step == "train" else 2.0
    return mult * active_params(cfg) * tokens
