"""Universal decoder-only LM: dense GQA, squared-ReLU, MLA, MoE, VLM.

Covers granite-8b/34b, nemotron-4-340b, yi-34b, chameleon-34b (token ids
already include the VQ image range — frontend stub per assignment),
olmoe-1b-7b and deepseek-v2-lite-16b, through one config dataclass.

Layers are scanned (scan-over-layers with jax.checkpoint remat) so
lowering a 96-layer model is one rolled HLO loop; heterogeneous prefix
layers (deepseek's dense-MLP first layer) are unrolled separately.

Layer namespace (DESIGN.md §7): every projection answers to a workload
layer name — the per-layer gemm names ``q``/``k``/``v``/``o`` (MLA:
``q``/``dkv``/``uk``/``uv``/``o``), ``mlp``, ``expert``/``shared`` and
the boundary ``head`` — optionally scoped to one decoder layer as
``l{i}.name``.  A ``PrecisionPlan`` with depth-scoped entries makes the
layer stack format-heterogeneous; since per-layer plane counts break a
homogeneous ``lax.scan``, the stack is partitioned into contiguous
FORMAT GROUPS (one scan per run of identical per-layer formats,
order-preserving) at spec, QAT-forward, pack and serve time alike.
The uniform case is the degenerate single group — byte-identical trees
and graphs to the pre-plan behavior.

Three entry points per mode:
  forward      — full-sequence teacher-forced logits (train / eval)
  prefill      — full-sequence forward that also returns the KV cache
  decode_step  — one token against the cache (serve_step of the shapes)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import plan as plan_lib
from repro.core.dse import Gemm
from repro.core.precision import PrecisionPolicy
from repro.nn import attention as attn
from repro.nn import kvcache
from repro.nn import layers as nnl
from repro.nn import moe as nnmoe
from repro.nn import quantized as Q
from repro.nn.moe import MoEConfig
from repro.nn.param import ParamSpec
from repro.nn.partitioning import constrain

__all__ = ["MLAConfig", "TransformerConfig", "specs", "forward", "prefill",
           "decode_step", "decode_steps", "cache_specs", "gemm_workload",
           "model_flops", "plan_layer_names", "kv_layer_names",
           "kv_cache_workload", "scan_format_groups", "regroup_layers"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    act: str = "swiglu"            # 'swiglu' | 'sq_relu' | 'gelu'
    norm: str = "rms"
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rope_base: float = 10000.0
    scan_layers: bool = True
    scan_unroll: bool = False      # dry-run probes: straightline the stack
    remat: bool = True
    remat_policy: str = "full"     # 'full' | 'dots' (save matmul outputs)
    attn_impl: str = "xla"         # 'xla' | 'flash' (Pallas, serve prefill)
    dense_first_n: int = 0         # deepseek: first N layers use a dense MLP
    dense_ff: int = 0
    attn_chunk: int = 1024
    family: str = "dense"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def norm_fns(self):
        if self.norm == "rms":
            return nnl.rmsnorm_spec, nnl.rmsnorm_apply
        return nnl.layernorm_spec, nnl.layernorm_apply


# --------------------------------------------------------------------------
# Layer namespace + format groups
# --------------------------------------------------------------------------


def _layer_bases(cfg: TransformerConfig, dense_mlp: bool) -> Tuple[str, ...]:
    """Base workload layer names of one decoder layer."""
    a = (("q", "dkv", "uk", "uv", "o") if cfg.mla is not None
         else ("q", "k", "v", "o"))
    if cfg.moe is not None and not dense_mlp:
        m = ("expert",) + (("shared",) if cfg.moe.n_shared else ())
    else:
        m = ("mlp",)
    return a + m


def plan_layer_names(cfg: TransformerConfig) -> List[str]:
    """Every name a PrecisionPlan may bind for this config: the base
    per-projection names (one entry covers all depths) plus the
    depth-scoped ``l{i}.name`` forms, and the boundary ``head``."""
    names = {"head"}
    for i in range(cfg.n_layers):
        bases = _layer_bases(cfg, dense_mlp=i < cfg.dense_first_n)
        names.update(bases)
        names.update(f"l{i}.{b}" for b in bases)
    return sorted(names)


def kv_layer_names(cfg: TransformerConfig) -> List[str]:
    """Cached-tensor names a plan may bind ``kv_bits`` to: the decode
    cache holds one K and one V tensor per GQA layer.  Empty for MLA —
    the latent ``c_kv`` cache is not a per-head tensor and stays bf16."""
    if cfg.mla is not None:
        return []
    names = {"k", "v"}
    for i in range(cfg.n_layers):
        names.update((f"l{i}.k", f"l{i}.v"))
    return sorted(names)


def kv_cache_workload(cfg: TransformerConfig) -> Dict[str, Tuple[int, int]]:
    """{cached tensor name: (kv_heads, head_dim)} — the decode-cache
    analogue of ``gemm_workload`` for footprint/planner accounting."""
    if cfg.mla is not None:
        return {}
    return {f"l{i}.{t}": (cfg.n_kv, cfg.hd)
            for i in range(cfg.n_layers) for t in ("k", "v")}


def _kv_fmt(cfg, policy, name: str) -> Optional[kvcache.KVFormat]:
    bits = plan_lib.resolve_kv_bits(policy, name)
    if bits is None:
        return None
    return kvcache.KVFormat(bits, policy.kv_slice(bits), cfg.hd)


def _kv_formats(cfg, policy):
    """None for fp caches, else ``(store, [(fmt_k, fmt_v)] per depth)``.

    The single gate every cache-shaped code path asks; a plan whose kv
    keys never resolve onto this config's layers degenerates to None.
    """
    if not isinstance(policy, plan_lib.PrecisionPlan) \
            or not policy.kv_enabled():
        return None
    fmts = [(_kv_fmt(cfg, policy, f"l{i}.k"), _kv_fmt(cfg, policy, f"l{i}.v"))
            for i in range(cfg.n_layers)]
    if all(fk is None and fv is None for fk, fv in fmts):
        return None
    if cfg.mla is not None:
        raise ValueError(
            f"plan {policy.name or '<unnamed>'!r} sets KV-cache "
            f"word-lengths but {cfg.name} uses MLA latent caches, which "
            f"have no per-head K/V tensors to quantize")
    if cfg.dense_first_n:
        raise ValueError("KV-cache quantization does not support "
                         "dense-prefix (unrolled) layer stacks")
    return policy.kv_store(), fmts


def _layer_signature(cfg, policy, i: int):
    """The format tuple that decides scan-group membership of depth i."""
    sig = tuple(plan_lib.resolve_policy(policy, f"l{i}.{b}")
                for b in _layer_bases(cfg, dense_mlp=False))
    # cache formats live in the scanned cache leaves, so they gate group
    # membership exactly like weight formats do
    return sig + (plan_lib.resolve_kv_bits(policy, f"l{i}.k"),
                  plan_lib.resolve_kv_bits(policy, f"l{i}.v"))


def scan_format_groups(cfg: TransformerConfig, policy) -> List[Tuple[int, int]]:
    """Partition the scanned stack into contiguous runs of identical
    per-layer formats: [(start_depth, length), ...] in depth order.

    A uniform policy (or a plan with no depth-scoped entries) yields one
    group — the pre-plan homogeneous scan.  Heterogeneous plans get one
    ``lax.scan`` per run; order is preserved so the residual-stream
    carry threads the layers exactly as before.
    """
    groups: List[List[int]] = []
    prev_sig = None
    for i in range(cfg.dense_first_n, cfg.n_layers):
        sig = _layer_signature(cfg, policy, i)
        if groups and sig == prev_sig:
            groups[-1][1] += 1
        else:
            groups.append([i, 1])
            prev_sig = sig
    return [tuple(g) for g in groups]


def _layer_groups(cfg, params_layers, policy):
    """[(lname_prefix, group_param_subtree, start, length)] for iterating
    the (possibly grouped) 'layers' entry of a param/spec tree."""
    groups = scan_format_groups(cfg, policy)
    if len(groups) == 1:
        s, n = groups[0]
        return [(f"l{s}.", params_layers, s, n)]
    return [(f"l{s}.", params_layers[f"g{j}"], s, n)
            for j, (s, n) in enumerate(groups)]


def regroup_layers(cfg, params, policy):
    """Re-layout a param tree's 'layers' stack to ``policy``'s format
    groups.

    The deployment flow is train ONCE (uniform QAT, one homogeneous
    stack), then re-pack per plan point: a depth-heterogeneous plan
    needs the stack split into its format groups before the per-group
    formats can differ.  Slicing the lead axis per group is exactly the
    paper's re-pack — no parameter changes, just layout.  Identity when
    the plan is uniform or the tree is already grouped.
    """
    if "layers" not in params:
        return params
    groups = scan_format_groups(cfg, policy)
    lp = params["layers"]

    def lead_len(tree):
        # first leaf with a real lead extent (robust to zero-size leaves)
        for leaf in jax.tree.leaves(tree):
            if getattr(leaf, "ndim", 0) and leaf.shape[0]:
                return leaf.shape[0]
        return None

    if isinstance(lp, dict) and "g0" in lp:
        if len(lp) == len(groups) and all(
                lead_len(lp[f"g{j}"]) == n
                for j, (_s, n) in enumerate(groups)):
            return params  # already in this plan's group layout
        # flatten a foreign group layout back to one stack (depth order)
        parts = [lp[f"g{j}"] for j in range(len(lp))]
        lp = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)
    if len(groups) == 1:
        out = dict(params)
        out["layers"] = lp
        return out
    nd = cfg.dense_first_n
    out = dict(params)
    out["layers"] = {
        f"g{j}": jax.tree.map(lambda a, _s=s, _n=n: a[_s - nd:_s - nd + _n],
                              lp)
        for j, (s, n) in enumerate(groups)
    }
    return out


# --------------------------------------------------------------------------
# Specs
# --------------------------------------------------------------------------


def _mlp_spec(cfg, d_ff, *, lead, lead_axes, serve, policy, lname=""):
    mk = functools.partial(
        Q.qlinear_serve_spec if serve else Q.qlinear_spec,
        lead=lead, lead_axes=lead_axes, name=lname + "mlp",
    )
    kw = {"policy": policy} if serve else {}
    if cfg.act == "swiglu":
        return {
            "gate": mk(cfg.d_model, d_ff, axes=("embed", "mlp"), **kw),
            "up": mk(cfg.d_model, d_ff, axes=("embed", "mlp"), **kw),
            "down": mk(d_ff, cfg.d_model, axes=("mlp", "act_embed"), **kw),
        }
    return {  # sq_relu / gelu: two-matrix MLP
        "up": mk(cfg.d_model, d_ff, axes=("embed", "mlp"), **kw),
        "down": mk(d_ff, cfg.d_model, axes=("mlp", "act_embed"), **kw),
    }


def _attn_spec(cfg, *, lead, lead_axes, serve, policy, lname=""):
    if cfg.mla is not None:
        return attn.mla_spec(
            cfg.d_model, cfg.n_heads,
            kv_lora=cfg.mla.kv_lora, qk_nope=cfg.mla.qk_nope,
            qk_rope=cfg.mla.qk_rope, v_head=cfg.mla.v_head,
            lead=lead, lead_axes=lead_axes, serve=serve, policy=policy,
            lname=lname)
    return attn.gqa_spec(cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd,
                         lead=lead, lead_axes=lead_axes, serve=serve,
                         policy=policy, lname=lname)


def _layer_spec(cfg, *, lead, lead_axes, serve, policy, dense_mlp=False,
                lname=""):
    nspec, _ = cfg.norm_fns
    stack = lambda s: {k: ParamSpec(shape=lead + v.shape, dtype=v.dtype,
                                    axes=lead_axes + v.axes, init=v.init,
                                    const=v.const)
                       for k, v in s.items()}
    spec = {
        "ln1": stack(nspec(cfg.d_model)),
        "ln2": stack(nspec(cfg.d_model)),
        "attn": _attn_spec(cfg, lead=lead, lead_axes=lead_axes, serve=serve,
                           policy=policy, lname=lname),
    }
    if cfg.moe is not None and not dense_mlp:
        spec["moe"] = nnmoe.moe_spec(cfg.moe, lead=lead, lead_axes=lead_axes,
                                     serve=serve, policy=policy, lname=lname)
    else:
        ff = cfg.dense_ff if dense_mlp and cfg.dense_ff else cfg.d_ff
        spec["mlp"] = _mlp_spec(cfg, ff, lead=lead, lead_axes=lead_axes,
                                serve=serve, policy=policy, lname=lname)
    return spec


def specs(cfg: TransformerConfig, mode: str = "train",
          policy: PrecisionPolicy = PrecisionPolicy()) -> Dict:
    """Full parameter-spec tree for one mode ('train' | 'serve').

    ``policy`` may be a ``PrecisionPlan``; with depth-scoped entries the
    'layers' stack splits into format groups ``{'g0': ..., 'g1': ...}``
    (one stacked subtree per contiguous run of identical formats), each
    layer at its own (w_bits, k) spec shapes.  The uniform case keeps
    the single stacked subtree — byte-identical to the pre-plan tree.
    """
    serve = mode == "serve"
    nspec, _ = cfg.norm_fns
    n_scan = cfg.n_layers - cfg.dense_first_n
    vp = nnl.pad_vocab(cfg.vocab)
    groups = scan_format_groups(cfg, policy)
    if len(groups) == 1:
        s0 = groups[0][0]
        layers_spec = _layer_spec(
            cfg, lead=(n_scan,) if cfg.scan_layers else (),
            lead_axes=("layers",) if cfg.scan_layers else (),
            serve=serve, policy=policy, lname=f"l{s0}.")
    else:
        layers_spec = {
            f"g{j}": _layer_spec(cfg, lead=(n,), lead_axes=("layers",),
                                 serve=serve, policy=policy, lname=f"l{s}.")
            for j, (s, n) in enumerate(groups)
        }
    tree: Dict[str, Any] = {
        "embed": (nnl.embed_serve_spec(vp, cfg.d_model, policy)
                  if serve else nnl.embed_spec(vp, cfg.d_model)),
        "final_norm": nspec(cfg.d_model),
        "head": (Q.qlinear_serve_spec(cfg.d_model, vp,
                                      axes=("embed", "vocab"),
                                      layer_class="boundary", policy=policy,
                                      name="head")
                 if serve else
                 Q.qlinear_spec(cfg.d_model, vp, axes=("embed", "vocab"),
                                layer_class="boundary", name="head")),
        "layers": layers_spec,
    }
    if not cfg.scan_layers and n_scan > 1:
        raise ValueError("unscanned multi-layer stacks not supported; "
                         "set scan_layers=True")
    for i in range(cfg.dense_first_n):
        tree[f"dense_layer_{i}"] = _layer_spec(
            cfg, lead=(), lead_axes=(), serve=serve, policy=policy,
            dense_mlp=True, lname=f"l{i}.")
    return tree


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _apply_mlp(cfg, p, x, policy, serve, impl, dense_mlp=False, lname=""):
    fn = (functools.partial(Q.qlinear_serve_apply, impl=impl)
          if serve else Q.qlinear_apply)
    if cfg.moe is not None and not dense_mlp:
        return nnmoe.moe_apply(p["moe"], x, policy, cfg.moe, serve=serve,
                               impl=impl, lname=lname)
    mp = p["mlp"]
    nm = lname + "mlp"
    if cfg.act == "swiglu":
        g = fn(mp["gate"], x, policy, name=nm)
        u = fn(mp["up"], x, policy, name=nm)
        h = nnl.swiglu_combine(g, u)
    else:
        h = fn(mp["up"], x, policy, name=nm)
        h = nnl.squared_relu(h) if cfg.act == "sq_relu" else nnl.gelu(h)
    return fn(mp["down"], h, policy, name=nm)


def _layer_fwd(cfg, p, x, policy, sin, cos, *, serve, impl, dense_mlp=False,
               lname="", kv_fmts=None, kv_store="packed"):
    """Pre-norm block; returns (x, kv_cache_of_layer)."""
    _, napply = cfg.norm_fns
    h = napply(p["ln1"], x)
    if cfg.mla is not None:
        o, cache = attn.mla_prefill(
            p["attn"], h, policy, n_heads=cfg.n_heads,
            kv_lora=cfg.mla.kv_lora, qk_nope=cfg.mla.qk_nope,
            qk_rope=cfg.mla.qk_rope, v_head=cfg.mla.v_head,
            sin=sin, cos=cos, serve=serve, impl=impl, chunk=cfg.attn_chunk,
            lname=lname)
    else:
        o, cache = attn.gqa_prefill(
            p["attn"], h, policy, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.hd, sin=sin, cos=cos, serve=serve, impl=impl,
            chunk=cfg.attn_chunk, attn_impl=cfg.attn_impl, lname=lname,
            kv_fmts=kv_fmts, kv_store=kv_store)
    x = x + o
    x = constrain(x, ("batch", "seq", "act_embed"))
    h = napply(p["ln2"], x)
    x = x + _apply_mlp(cfg, p, h, policy, serve, impl, dense_mlp, lname)
    return constrain(x, ("batch", "seq", "act_embed")), cache


def _embed(cfg, params, tokens, serve):
    if serve:
        return nnl.embed_serve_apply(params["embed"], tokens)
    return nnl.embed_apply(params["embed"], tokens)


def _head(cfg, params, x, policy, serve, impl):
    _, napply = cfg.norm_fns
    x = napply(params["final_norm"], x)
    if serve:
        logits = Q.qlinear_serve_apply(params["head"], x, policy,
                                       layer_class="boundary", impl=impl,
                                       name="head")
    else:
        logits = Q.qlinear_apply(params["head"], x, policy,
                                 layer_class="boundary", name="head")
    return logits[..., :cfg.vocab]  # drop TP vocab padding


def _body_constrain(cfg, lp, serve, policy, lname=""):
    """Re-pin the per-layer param slice to its FSDP sharding inside the
    scan body.  Without this, GSPMD hoists the weight all-gather out of
    the layer loop and materializes EVERY layer's gathered f32 weights at
    once (+8.5 GiB/device for granite-34b — §Perf, FSDP-scan fix); the
    constraint keeps the stacked master sharded so each iteration gathers
    only its own slice, which remat then frees."""
    spec = _layer_spec(cfg, lead=(), lead_axes=(), serve=serve, policy=policy,
                       lname=lname)

    def rec(sp, leaf):
        if isinstance(sp, ParamSpec):
            if hasattr(leaf, "ndim") and leaf.ndim == len(sp.axes):
                return constrain(leaf, sp.axes)
            return leaf
        if isinstance(sp, dict) and isinstance(leaf, dict):
            # iterate the PARAM keys: spec may carry extra marker entries
            return {k: rec(sp.get(k), v) for k, v in leaf.items()}
        return leaf

    return rec(spec, lp)


def _run_layers(cfg, params, x, policy, sin, cos, *, serve, impl,
                collect_cache: bool):
    """Dense-prefix layers unrolled, the remainder scanned — one scan per
    format group (heterogeneous plans), order-preserving."""
    params = regroup_layers(cfg, params, policy)
    kv_info = _kv_formats(cfg, policy)
    kv_store = kv_info[0] if kv_info is not None else "packed"
    kv_packed = kv_info is not None and kv_store == "packed"
    cache_parts = []
    for i in range(cfg.dense_first_n):
        x, cache_i = _layer_fwd(cfg, params[f"dense_layer_{i}"], x, policy,
                                sin, cos, serve=serve, impl=impl,
                                dense_mlp=True, lname=f"l{i}.")
        if collect_cache:
            cache_parts.append(jax.tree.map(lambda v: v[None], cache_i))

    pol = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
           if cfg.remat_policy == "dots" else None)
    for lname, lp_group, _s, _n in _layer_groups(cfg, params["layers"],
                                                 policy):
        fmts_g = kv_info[1][_s] if kv_info is not None else None

        def body(carry, lp, _lname=lname, _fmts=fmts_g):
            lp = _body_constrain(cfg, lp, serve, policy, _lname)
            y, cache = _layer_fwd(cfg, lp, carry, policy, sin, cos,
                                  serve=serve, impl=impl, lname=_lname,
                                  kv_fmts=_fmts, kv_store=kv_store)
            return y, cache if collect_cache else None

        fn = jax.checkpoint(body, policy=pol) if cfg.remat else body
        x, caches = jax.lax.scan(fn, x, lp_group,
                                 unroll=True if cfg.scan_unroll else 1)
        if collect_cache:
            cache_parts.append(caches)
    if not collect_cache:
        return x, None
    if kv_packed:
        # packed caches stay group-keyed: per-group leaf shapes differ
        # (plane counts), so there is no cross-group stack to rebuild
        return x, {f"g{j}": part for j, part in enumerate(cache_parts)}
    caches = (cache_parts[0] if len(cache_parts) == 1 else
              jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                           *cache_parts))
    return x, caches


def forward(cfg: TransformerConfig, params, tokens: jax.Array,
            policy: PrecisionPolicy, *, mode: str = "train",
            impl: str = "xla") -> jax.Array:
    """tokens (B, S) -> logits (B, S, V)."""
    serve = mode == "serve"
    b, s = tokens.shape
    x = _embed(cfg, params, tokens, serve)
    x = constrain(x, ("batch", "seq", "act_embed"))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    rope_dim = cfg.mla.qk_rope if cfg.mla is not None else cfg.hd
    sin, cos = nnl.rotary_cache(pos, rope_dim, cfg.rope_base)
    x, _ = _run_layers(cfg, params, x, policy, sin, cos, serve=serve,
                       impl=impl, collect_cache=False)
    return _head(cfg, params, x, policy, serve, impl)


def prefill(cfg: TransformerConfig, params, tokens: jax.Array,
            policy: PrecisionPolicy, *, impl: str = "xla",
            mode: str = "serve"):
    """tokens (B,S) -> (last-token logits (B,V), cache pytree, length)."""
    serve = mode == "serve"
    b, s = tokens.shape
    x = _embed(cfg, params, tokens, serve)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    rope_dim = cfg.mla.qk_rope if cfg.mla is not None else cfg.hd
    sin, cos = nnl.rotary_cache(pos, rope_dim, cfg.rope_base)
    x, caches = _run_layers(cfg, params, x, policy, sin, cos, serve=serve,
                            impl=impl, collect_cache=True)
    logits = _head(cfg, params, x[:, -1:, :], policy, serve, impl)
    return logits[:, 0, :], caches


def cache_specs(cfg: TransformerConfig, batch: int, max_len: int,
                policy=None):
    """ShapeDtypeStructs of the decode cache (stacked over layers).

    A kv-carrying plan with ``store='packed'`` swaps the bf16 (K, V)
    tuple for a group-keyed tree of digit-plane uint8 codes plus bf16
    scale/zero per (token, head); 'qdq' and fp plans keep the legacy
    bf16 tuple layout exactly.
    """
    l = cfg.n_layers
    if cfg.mla is not None:
        _kv_formats(cfg, policy)  # raises on kv-carrying plans
        return (
            jax.ShapeDtypeStruct((l, batch, max_len, cfg.mla.kv_lora), jnp.bfloat16),
            jax.ShapeDtypeStruct((l, batch, max_len, cfg.mla.qk_rope), jnp.bfloat16),
        )
    kv_info = _kv_formats(cfg, policy)
    if kv_info is not None and kv_info[0] == "packed":
        store, fmts = kv_info
        sds = jax.ShapeDtypeStruct

        def tensor_spec(fmt, n):
            if fmt is None:
                return sds((n, batch, max_len, cfg.n_kv, cfg.hd),
                           jnp.bfloat16)
            return {
                "p": sds((n, fmt.planes, batch, max_len, cfg.n_kv,
                          fmt.packed_d), jnp.uint8),
                "s": sds((n, batch, max_len, cfg.n_kv), jnp.bfloat16),
                "z": sds((n, batch, max_len, cfg.n_kv), jnp.bfloat16),
            }

        return {f"g{j}": {"k": tensor_spec(fmts[s][0], n),
                          "v": tensor_spec(fmts[s][1], n)}
                for j, (s, n) in enumerate(scan_format_groups(cfg, policy))}
    return (
        jax.ShapeDtypeStruct((l, batch, max_len, cfg.n_kv, cfg.hd), jnp.bfloat16),
        jax.ShapeDtypeStruct((l, batch, max_len, cfg.n_kv, cfg.hd), jnp.bfloat16),
    )


def cache_axes(cfg: TransformerConfig, policy=None):
    """Logical axes of the cache (for sharding)."""
    if cfg.mla is not None:
        return (("layers", "batch", "kv_seq", None),
                ("layers", "batch", "kv_seq", None))
    kv_info = _kv_formats(cfg, policy)
    if kv_info is not None and kv_info[0] == "packed":
        store, fmts = kv_info

        def tensor_axes(fmt):
            if fmt is None:
                return ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
            return {"p": ("layers", None, "batch", "kv_seq", "kv_heads",
                          None),
                    "s": ("layers", "batch", "kv_seq", "kv_heads"),
                    "z": ("layers", "batch", "kv_seq", "kv_heads")}

        return {f"g{j}": {"k": tensor_axes(fmts[s][0]),
                          "v": tensor_axes(fmts[s][1])}
                for j, (s, _n) in enumerate(scan_format_groups(cfg, policy))}
    return (("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
            ("layers", "batch", "kv_seq", "kv_heads", "head_dim"))


def decode_step(cfg: TransformerConfig, params, cache, tokens: jax.Array,
                length: jax.Array, policy: PrecisionPolicy,
                *, impl: str = "xla", mode: str = "serve"):
    """One new token. tokens (B, 1); cache from cache_specs.

    Returns (logits (B, V), new cache).
    """
    serve = mode == "serve"
    params = regroup_layers(cfg, params, policy)
    kv_info = _kv_formats(cfg, policy)
    kv_store = kv_info[0] if kv_info is not None else "packed"
    b = tokens.shape[0]
    x = _embed(cfg, params, tokens, serve)
    pos = jnp.broadcast_to(length[None, None] if length.ndim == 0 else length,
                           (b, 1))
    rope_dim = cfg.mla.qk_rope if cfg.mla is not None else cfg.hd
    sin, cos = nnl.rotary_cache(pos, rope_dim, cfg.rope_base)

    def one_layer(x, lp, c, dense_mlp=False, lname="", fmts=None):
        _, napply = cfg.norm_fns
        h = napply(lp["ln1"], x)
        if cfg.mla is not None:
            o, c = attn.mla_decode(
                lp["attn"], h, c, length, policy,
                n_heads=cfg.n_heads, kv_lora=cfg.mla.kv_lora,
                qk_nope=cfg.mla.qk_nope, qk_rope=cfg.mla.qk_rope,
                v_head=cfg.mla.v_head, sin=sin, cos=cos, serve=serve,
                impl=impl, lname=lname)
        else:
            o, c = attn.gqa_decode(
                lp["attn"], h, c, length, policy,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
                sin=sin, cos=cos, serve=serve, impl=impl, lname=lname,
                kv_fmts=fmts, kv_store=kv_store)
        x = x + o
        h = napply(lp["ln2"], x)
        x = x + _apply_mlp(cfg, lp, h, policy, serve, impl, dense_mlp, lname)
        return x, c

    if kv_info is not None and kv_store == "packed":
        # group-keyed packed cache: no cross-group stacking — each scan
        # updates its own group subtree in place (appends stay packed)
        new_cache = {}
        for j, (lname, lp_group, start, n) in enumerate(
                _layer_groups(cfg, params["layers"], policy)):
            fmts_g = kv_info[1][start]

            def body(carry, xs, _lname=lname, _fmts=fmts_g):
                lp, cg = xs
                y, cg = one_layer(carry, lp, cg, lname=_lname, fmts=_fmts)
                return y, cg

            x, cg_new = jax.lax.scan(
                body, x, (lp_group, cache[f"g{j}"]),
                unroll=True if cfg.scan_unroll else 1)
            new_cache[f"g{j}"] = cg_new
        logits = _head(cfg, params, x, policy, serve, impl)
        return logits[:, 0, :], new_cache

    c1_all, c2_all = cache
    nd = cfg.dense_first_n
    c1_parts, c2_parts = [], []
    for i in range(nd):
        x, (c1_i, c2_i) = one_layer(x, params[f"dense_layer_{i}"],
                                    (c1_all[i], c2_all[i]), dense_mlp=True,
                                    lname=f"l{i}.")
        c1_parts.append(c1_i[None])
        c2_parts.append(c2_i[None])

    # One scan per format group (uniform plans: exactly one), the cache
    # stack sliced to the group's depth range.
    for lname, lp_group, start, n in _layer_groups(cfg, params["layers"],
                                                   policy):
        fmts_g = kv_info[1][start] if kv_info is not None else None

        def body(carry, xs, _lname=lname, _fmts=fmts_g):
            lp, c1, c2 = xs
            y, (c1, c2) = one_layer(carry, lp, (c1, c2), lname=_lname,
                                    fmts=_fmts)
            return y, (c1, c2)

        x, (c1_g, c2_g) = jax.lax.scan(
            body, x, (lp_group, c1_all[start:start + n],
                      c2_all[start:start + n]),
            unroll=True if cfg.scan_unroll else 1)
        c1_parts.append(c1_g)
        c2_parts.append(c2_g)
    c1_s = (c1_parts[0] if len(c1_parts) == 1
            else jnp.concatenate(c1_parts, axis=0))
    c2_s = (c2_parts[0] if len(c2_parts) == 1
            else jnp.concatenate(c2_parts, axis=0))
    logits = _head(cfg, params, x, policy, serve, impl)
    return logits[:, 0, :], (c1_s, c2_s)


def decode_steps(cfg: TransformerConfig, params, cache, tokens: jax.Array,
                 length: jax.Array, policy: PrecisionPolicy,
                 *, impl: str = "xla", mode: str = "serve",
                 attn_impl: str = "xla"):
    """T new tokens against the cache in ONE forward — the speculative
    verify step.  tokens (B, T) are appended at positions
    ``length .. length+T-1``; returns (logits (B, T, V), new cache)
    where logits[:, t] is the next-token row after tokens[:, :t+1].

    Bit-identity contract (tests/test_specdec.py): the T logits rows
    equal T sequential ``decode_step`` calls over the same tokens —
    weight matmuls accumulate in exact int32 (mpmm), norms/rotary/
    activation quantization are per-row, KV block packing equals
    per-token packing, and attention runs the identical single-query
    routine per position with rows beyond each query's valid length
    contributing an exact zero.
    """
    serve = mode == "serve"
    params = regroup_layers(cfg, params, policy)
    kv_info = _kv_formats(cfg, policy)
    kv_store = kv_info[0] if kv_info is not None else "packed"
    b, t_new = tokens.shape
    x = _embed(cfg, params, tokens, serve)
    lv = jnp.asarray(length)  # length may be a static int (flash verify)
    pos = jnp.broadcast_to(lv[None, None] if lv.ndim == 0 else lv,
                           (b, 1)) + jnp.arange(t_new)[None, :]
    rope_dim = cfg.mla.qk_rope if cfg.mla is not None else cfg.hd
    sin, cos = nnl.rotary_cache(pos, rope_dim, cfg.rope_base)

    def one_layer(x, lp, c, dense_mlp=False, lname="", fmts=None):
        _, napply = cfg.norm_fns
        h = napply(lp["ln1"], x)
        if cfg.mla is not None:
            o, c = attn.mla_verify(
                lp["attn"], h, c, length, policy,
                n_heads=cfg.n_heads, kv_lora=cfg.mla.kv_lora,
                qk_nope=cfg.mla.qk_nope, qk_rope=cfg.mla.qk_rope,
                v_head=cfg.mla.v_head, sin=sin, cos=cos, serve=serve,
                impl=impl, lname=lname)
        else:
            o, c = attn.gqa_verify(
                lp["attn"], h, c, length, policy,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
                sin=sin, cos=cos, serve=serve, impl=impl,
                attn_impl=attn_impl, lname=lname,
                kv_fmts=fmts, kv_store=kv_store)
        x = x + o
        h = napply(lp["ln2"], x)
        x = x + _apply_mlp(cfg, lp, h, policy, serve, impl, dense_mlp, lname)
        return x, c

    if kv_info is not None and kv_store == "packed":
        new_cache = {}
        for j, (lname, lp_group, start, n) in enumerate(
                _layer_groups(cfg, params["layers"], policy)):
            fmts_g = kv_info[1][start]

            def body(carry, xs, _lname=lname, _fmts=fmts_g):
                lp, cg = xs
                y, cg = one_layer(carry, lp, cg, lname=_lname, fmts=_fmts)
                return y, cg

            x, cg_new = jax.lax.scan(
                body, x, (lp_group, cache[f"g{j}"]),
                unroll=True if cfg.scan_unroll else 1)
            new_cache[f"g{j}"] = cg_new
        return _head(cfg, params, x, policy, serve, impl), new_cache

    c1_all, c2_all = cache
    nd = cfg.dense_first_n
    c1_parts, c2_parts = [], []
    for i in range(nd):
        x, (c1_i, c2_i) = one_layer(x, params[f"dense_layer_{i}"],
                                    (c1_all[i], c2_all[i]), dense_mlp=True,
                                    lname=f"l{i}.")
        c1_parts.append(c1_i[None])
        c2_parts.append(c2_i[None])
    for lname, lp_group, start, n in _layer_groups(cfg, params["layers"],
                                                   policy):
        fmts_g = kv_info[1][start] if kv_info is not None else None

        def body(carry, xs, _lname=lname, _fmts=fmts_g):
            lp, c1, c2 = xs
            y, (c1, c2) = one_layer(carry, lp, (c1, c2), lname=_lname,
                                    fmts=_fmts)
            return y, (c1, c2)

        x, (c1_g, c2_g) = jax.lax.scan(
            body, x, (lp_group, c1_all[start:start + n],
                      c2_all[start:start + n]),
            unroll=True if cfg.scan_unroll else 1)
        c1_parts.append(c1_g)
        c2_parts.append(c2_g)
    c1_s = (c1_parts[0] if len(c1_parts) == 1
            else jnp.concatenate(c1_parts, axis=0))
    c2_s = (c2_parts[0] if len(c2_parts) == 1
            else jnp.concatenate(c2_parts, axis=0))
    return _head(cfg, params, x, policy, serve, impl), (c1_s, c2_s)


# --------------------------------------------------------------------------
# Workload descriptions (DSE, roofline)
# --------------------------------------------------------------------------


def _per_layer_gemms(cfg: TransformerConfig, tokens: int):
    """GEMMs of one decoder layer at `tokens` activations rows."""
    d, hd = cfg.d_model, cfg.hd
    out = []
    if cfg.mla is not None:
        m = cfg.mla
        out += [
            Gemm("q", tokens, d, cfg.n_heads * (m.qk_nope + m.qk_rope)),
            Gemm("dkv", tokens, d, m.kv_lora + m.qk_rope),
            Gemm("uk", tokens, m.kv_lora, cfg.n_heads * m.qk_nope),
            Gemm("uv", tokens, m.kv_lora, cfg.n_heads * m.v_head),
            Gemm("o", tokens, cfg.n_heads * m.v_head, d),
        ]
    else:
        out += [
            Gemm("q", tokens, d, cfg.n_heads * hd),
            Gemm("k", tokens, d, cfg.n_kv * hd),
            Gemm("v", tokens, d, cfg.n_kv * hd),
            Gemm("o", tokens, cfg.n_heads * hd, d),
        ]
    if cfg.moe is not None:
        mc = cfg.moe
        act_tokens = tokens * mc.topk  # tokens routed through experts
        n_mats = 3 if cfg.act == "swiglu" else 2
        out += [Gemm("expert", act_tokens, d, mc.d_ff, count=n_mats)]
        if mc.n_shared:
            out += [Gemm("shared", tokens, d, mc.shared_hidden, count=n_mats)]
    else:
        n_mats = 3 if cfg.act == "swiglu" else 2
        out += [Gemm("mlp", tokens, d, cfg.d_ff, count=n_mats)]
    return out


def gemm_workload(cfg: TransformerConfig, tokens: int):
    """All GEMMs of one full forward over `tokens` tokens (DSE input)."""
    gemms = []
    for g in _per_layer_gemms(cfg, tokens):
        gemms.append(dataclasses.replace(g, count=g.count * cfg.n_layers))
    gemms.append(Gemm("head", tokens, cfg.d_model, cfg.vocab,
                      layer_class="boundary"))
    return gemms


def active_params(cfg: TransformerConfig) -> int:
    """N_active: params touched per token (MoE counts topk+shared only)."""
    n = 0
    for g in _per_layer_gemms(cfg, 1):
        per = g.k * g.n * g.count
        if g.name == "expert":
            per = cfg.moe.topk * cfg.d_model * cfg.moe.d_ff * \
                (3 if cfg.act == "swiglu" else 2)
        n += per
    n *= cfg.n_layers
    n += 2 * cfg.vocab * cfg.d_model  # embed + head
    return n


def total_params(cfg: TransformerConfig) -> int:
    n = 0
    for g in _per_layer_gemms(cfg, 1):
        per = g.k * g.n * g.count
        if g.name == "expert":
            per = cfg.moe.n_experts * cfg.d_model * cfg.moe.d_ff * \
                (3 if cfg.act == "swiglu" else 2)
        n += per
    n *= cfg.n_layers
    n += 2 * cfg.vocab * cfg.d_model
    return n


def model_flops(cfg: TransformerConfig, *, tokens: int, step: str) -> float:
    """MODEL_FLOPS per step: 6·N_active·tokens (train), 2·N_active·tokens
    (prefill/decode) — the §Roofline 'useful flops' numerator."""
    n_active = active_params(cfg)
    mult = 6.0 if step == "train" else 2.0
    return mult * n_active * tokens
