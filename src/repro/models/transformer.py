"""Universal decoder-only LM: dense GQA, squared-ReLU, MLA, MoE, VLM.

Covers granite-8b/34b, nemotron-4-340b, yi-34b, chameleon-34b (token ids
already include the VQ image range — frontend stub per assignment),
olmoe-1b-7b and deepseek-v2-lite-16b, through one config dataclass.

Layers are scanned (scan-over-layers with jax.checkpoint remat) so
lowering a 96-layer model is one rolled HLO loop; heterogeneous prefix
layers (deepseek's dense-MLP first layer) are unrolled separately.

Three entry points per mode:
  forward      — full-sequence teacher-forced logits (train / eval)
  prefill      — full-sequence forward that also returns the KV cache
  decode_step  — one token against the cache (serve_step of the shapes)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dse import Gemm
from repro.core.precision import PrecisionPolicy
from repro.nn import attention as attn
from repro.nn import layers as nnl
from repro.nn import moe as nnmoe
from repro.nn import quantized as Q
from repro.nn.moe import MoEConfig
from repro.nn.param import ParamSpec
from repro.nn.partitioning import constrain

__all__ = ["MLAConfig", "TransformerConfig", "specs", "forward", "prefill",
           "decode_step", "cache_specs", "gemm_workload", "model_flops"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    act: str = "swiglu"            # 'swiglu' | 'sq_relu' | 'gelu'
    norm: str = "rms"
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rope_base: float = 10000.0
    scan_layers: bool = True
    scan_unroll: bool = False      # dry-run probes: straightline the stack
    remat: bool = True
    remat_policy: str = "full"     # 'full' | 'dots' (save matmul outputs)
    attn_impl: str = "xla"         # 'xla' | 'flash' (Pallas, serve prefill)
    dense_first_n: int = 0         # deepseek: first N layers use a dense MLP
    dense_ff: int = 0
    attn_chunk: int = 1024
    family: str = "dense"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def norm_fns(self):
        if self.norm == "rms":
            return nnl.rmsnorm_spec, nnl.rmsnorm_apply
        return nnl.layernorm_spec, nnl.layernorm_apply


# --------------------------------------------------------------------------
# Specs
# --------------------------------------------------------------------------


def _mlp_spec(cfg, d_ff, *, lead, lead_axes, serve, policy):
    mk = functools.partial(
        Q.qlinear_serve_spec if serve else Q.qlinear_spec,
        lead=lead, lead_axes=lead_axes,
    )
    kw = {"policy": policy} if serve else {}
    if cfg.act == "swiglu":
        return {
            "gate": mk(cfg.d_model, d_ff, axes=("embed", "mlp"), **kw),
            "up": mk(cfg.d_model, d_ff, axes=("embed", "mlp"), **kw),
            "down": mk(d_ff, cfg.d_model, axes=("mlp", "act_embed"), **kw),
        }
    return {  # sq_relu / gelu: two-matrix MLP
        "up": mk(cfg.d_model, d_ff, axes=("embed", "mlp"), **kw),
        "down": mk(d_ff, cfg.d_model, axes=("mlp", "act_embed"), **kw),
    }


def _attn_spec(cfg, *, lead, lead_axes, serve, policy):
    if cfg.mla is not None:
        return attn.mla_spec(
            cfg.d_model, cfg.n_heads,
            kv_lora=cfg.mla.kv_lora, qk_nope=cfg.mla.qk_nope,
            qk_rope=cfg.mla.qk_rope, v_head=cfg.mla.v_head,
            lead=lead, lead_axes=lead_axes, serve=serve, policy=policy)
    return attn.gqa_spec(cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd,
                         lead=lead, lead_axes=lead_axes, serve=serve,
                         policy=policy)


def _layer_spec(cfg, *, lead, lead_axes, serve, policy, dense_mlp=False):
    nspec, _ = cfg.norm_fns
    stack = lambda s: {k: ParamSpec(shape=lead + v.shape, dtype=v.dtype,
                                    axes=lead_axes + v.axes, init=v.init,
                                    const=v.const)
                       for k, v in s.items()}
    spec = {
        "ln1": stack(nspec(cfg.d_model)),
        "ln2": stack(nspec(cfg.d_model)),
        "attn": _attn_spec(cfg, lead=lead, lead_axes=lead_axes, serve=serve,
                           policy=policy),
    }
    if cfg.moe is not None and not dense_mlp:
        spec["moe"] = nnmoe.moe_spec(cfg.moe, lead=lead, lead_axes=lead_axes,
                                     serve=serve, policy=policy)
    else:
        ff = cfg.dense_ff if dense_mlp and cfg.dense_ff else cfg.d_ff
        spec["mlp"] = _mlp_spec(cfg, ff, lead=lead, lead_axes=lead_axes,
                                serve=serve, policy=policy)
    return spec


def specs(cfg: TransformerConfig, mode: str = "train",
          policy: PrecisionPolicy = PrecisionPolicy()) -> Dict:
    """Full parameter-spec tree for one mode ('train' | 'serve')."""
    serve = mode == "serve"
    nspec, _ = cfg.norm_fns
    n_scan = cfg.n_layers - cfg.dense_first_n
    vp = nnl.pad_vocab(cfg.vocab)
    tree: Dict[str, Any] = {
        "embed": (nnl.embed_serve_spec(vp, cfg.d_model, policy)
                  if serve else nnl.embed_spec(vp, cfg.d_model)),
        "final_norm": nspec(cfg.d_model),
        "head": (Q.qlinear_serve_spec(cfg.d_model, vp,
                                      axes=("embed", "vocab"),
                                      layer_class="boundary", policy=policy)
                 if serve else
                 Q.qlinear_spec(cfg.d_model, vp, axes=("embed", "vocab"),
                                layer_class="boundary")),
        "layers": _layer_spec(cfg, lead=(n_scan,) if cfg.scan_layers else (),
                              lead_axes=("layers",) if cfg.scan_layers else (),
                              serve=serve, policy=policy),
    }
    if not cfg.scan_layers and n_scan > 1:
        raise ValueError("unscanned multi-layer stacks not supported; "
                         "set scan_layers=True")
    for i in range(cfg.dense_first_n):
        tree[f"dense_layer_{i}"] = _layer_spec(
            cfg, lead=(), lead_axes=(), serve=serve, policy=policy,
            dense_mlp=True)
    return tree


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _apply_mlp(cfg, p, x, policy, serve, impl, dense_mlp=False):
    fn = (functools.partial(Q.qlinear_serve_apply, impl=impl)
          if serve else Q.qlinear_apply)
    if cfg.moe is not None and not dense_mlp:
        return nnmoe.moe_apply(p["moe"], x, policy, cfg.moe, serve=serve, impl=impl)
    mp = p["mlp"]
    if cfg.act == "swiglu":
        g, u = fn(mp["gate"], x, policy), fn(mp["up"], x, policy)
        h = nnl.swiglu_combine(g, u)
    else:
        h = fn(mp["up"], x, policy)
        h = nnl.squared_relu(h) if cfg.act == "sq_relu" else nnl.gelu(h)
    return fn(mp["down"], h, policy)


def _layer_fwd(cfg, p, x, policy, sin, cos, *, serve, impl, dense_mlp=False):
    """Pre-norm block; returns (x, kv_cache_of_layer)."""
    _, napply = cfg.norm_fns
    h = napply(p["ln1"], x)
    if cfg.mla is not None:
        o, cache = attn.mla_prefill(
            p["attn"], h, policy, n_heads=cfg.n_heads,
            kv_lora=cfg.mla.kv_lora, qk_nope=cfg.mla.qk_nope,
            qk_rope=cfg.mla.qk_rope, v_head=cfg.mla.v_head,
            sin=sin, cos=cos, serve=serve, impl=impl, chunk=cfg.attn_chunk)
    else:
        o, cache = attn.gqa_prefill(
            p["attn"], h, policy, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.hd, sin=sin, cos=cos, serve=serve, impl=impl,
            chunk=cfg.attn_chunk, attn_impl=cfg.attn_impl)
    x = x + o
    x = constrain(x, ("batch", "seq", "act_embed"))
    h = napply(p["ln2"], x)
    x = x + _apply_mlp(cfg, p, h, policy, serve, impl, dense_mlp)
    return constrain(x, ("batch", "seq", "act_embed")), cache


def _embed(cfg, params, tokens, serve):
    if serve:
        return nnl.embed_serve_apply(params["embed"], tokens)
    return nnl.embed_apply(params["embed"], tokens)


def _head(cfg, params, x, policy, serve, impl):
    _, napply = cfg.norm_fns
    x = napply(params["final_norm"], x)
    if serve:
        logits = Q.qlinear_serve_apply(params["head"], x, policy,
                                       layer_class="boundary", impl=impl)
    else:
        logits = Q.qlinear_apply(params["head"], x, policy,
                                 layer_class="boundary")
    return logits[..., :cfg.vocab]  # drop TP vocab padding


def _body_constrain(cfg, lp, serve, policy):
    """Re-pin the per-layer param slice to its FSDP sharding inside the
    scan body.  Without this, GSPMD hoists the weight all-gather out of
    the layer loop and materializes EVERY layer's gathered f32 weights at
    once (+8.5 GiB/device for granite-34b — §Perf, FSDP-scan fix); the
    constraint keeps the stacked master sharded so each iteration gathers
    only its own slice, which remat then frees."""
    spec = _layer_spec(cfg, lead=(), lead_axes=(), serve=serve, policy=policy)

    def rec(sp, leaf):
        if isinstance(sp, ParamSpec):
            if hasattr(leaf, "ndim") and leaf.ndim == len(sp.axes):
                return constrain(leaf, sp.axes)
            return leaf
        if isinstance(sp, dict) and isinstance(leaf, dict):
            # iterate the PARAM keys: spec may carry extra marker entries
            return {k: rec(sp.get(k), v) for k, v in leaf.items()}
        return leaf

    return rec(spec, lp)


def _run_layers(cfg, params, x, policy, sin, cos, *, serve, impl,
                collect_cache: bool):
    """Dense-prefix layers unrolled, the remainder scanned."""
    prefix_caches = []
    for i in range(cfg.dense_first_n):
        x, cache_i = _layer_fwd(cfg, params[f"dense_layer_{i}"], x, policy,
                                sin, cos, serve=serve, impl=impl, dense_mlp=True)
        if collect_cache:
            prefix_caches.append(cache_i)

    def body(carry, lp):
        lp = _body_constrain(cfg, lp, serve, policy)
        y, cache = _layer_fwd(cfg, lp, carry, policy, sin, cos,
                              serve=serve, impl=impl)
        return y, cache if collect_cache else None

    pol = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
           if cfg.remat_policy == "dots" else None)
    fn = jax.checkpoint(body, policy=pol) if cfg.remat else body
    x, caches = jax.lax.scan(fn, x, params["layers"],
                             unroll=True if cfg.scan_unroll else 1)
    if collect_cache and cfg.dense_first_n:
        pc = jax.tree.map(lambda *xs: jnp.stack(xs), *prefix_caches) \
            if cfg.dense_first_n > 1 else jax.tree.map(lambda v: v[None], prefix_caches[0])
        caches = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                              pc, caches)
    return x, caches


def forward(cfg: TransformerConfig, params, tokens: jax.Array,
            policy: PrecisionPolicy, *, mode: str = "train",
            impl: str = "xla") -> jax.Array:
    """tokens (B, S) -> logits (B, S, V)."""
    serve = mode == "serve"
    b, s = tokens.shape
    x = _embed(cfg, params, tokens, serve)
    x = constrain(x, ("batch", "seq", "act_embed"))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    rope_dim = cfg.mla.qk_rope if cfg.mla is not None else cfg.hd
    sin, cos = nnl.rotary_cache(pos, rope_dim, cfg.rope_base)
    x, _ = _run_layers(cfg, params, x, policy, sin, cos, serve=serve,
                       impl=impl, collect_cache=False)
    return _head(cfg, params, x, policy, serve, impl)


def prefill(cfg: TransformerConfig, params, tokens: jax.Array,
            policy: PrecisionPolicy, *, impl: str = "xla",
            mode: str = "serve"):
    """tokens (B,S) -> (last-token logits (B,V), cache pytree, length)."""
    serve = mode == "serve"
    b, s = tokens.shape
    x = _embed(cfg, params, tokens, serve)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    rope_dim = cfg.mla.qk_rope if cfg.mla is not None else cfg.hd
    sin, cos = nnl.rotary_cache(pos, rope_dim, cfg.rope_base)
    x, caches = _run_layers(cfg, params, x, policy, sin, cos, serve=serve,
                            impl=impl, collect_cache=True)
    logits = _head(cfg, params, x[:, -1:, :], policy, serve, impl)
    return logits[:, 0, :], caches


def cache_specs(cfg: TransformerConfig, batch: int, max_len: int):
    """ShapeDtypeStructs of the decode cache (stacked over layers)."""
    l = cfg.n_layers
    if cfg.mla is not None:
        return (
            jax.ShapeDtypeStruct((l, batch, max_len, cfg.mla.kv_lora), jnp.bfloat16),
            jax.ShapeDtypeStruct((l, batch, max_len, cfg.mla.qk_rope), jnp.bfloat16),
        )
    return (
        jax.ShapeDtypeStruct((l, batch, max_len, cfg.n_kv, cfg.hd), jnp.bfloat16),
        jax.ShapeDtypeStruct((l, batch, max_len, cfg.n_kv, cfg.hd), jnp.bfloat16),
    )


def cache_axes(cfg: TransformerConfig):
    """Logical axes of the cache (for sharding)."""
    if cfg.mla is not None:
        return (("layers", "batch", "kv_seq", None),
                ("layers", "batch", "kv_seq", None))
    return (("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
            ("layers", "batch", "kv_seq", "kv_heads", "head_dim"))


def decode_step(cfg: TransformerConfig, params, cache, tokens: jax.Array,
                length: jax.Array, policy: PrecisionPolicy,
                *, impl: str = "xla", mode: str = "serve"):
    """One new token. tokens (B, 1); cache from cache_specs.

    Returns (logits (B, V), new cache).
    """
    serve = mode == "serve"
    b = tokens.shape[0]
    x = _embed(cfg, params, tokens, serve)
    pos = jnp.broadcast_to(length[None, None] if length.ndim == 0 else length,
                           (b, 1))
    rope_dim = cfg.mla.qk_rope if cfg.mla is not None else cfg.hd
    sin, cos = nnl.rotary_cache(pos, rope_dim, cfg.rope_base)

    def one_layer(x, lp, c1, c2, dense_mlp=False):
        _, napply = cfg.norm_fns
        h = napply(lp["ln1"], x)
        if cfg.mla is not None:
            o, (c1, c2) = attn.mla_decode(
                lp["attn"], h, (c1, c2), length, policy,
                n_heads=cfg.n_heads, kv_lora=cfg.mla.kv_lora,
                qk_nope=cfg.mla.qk_nope, qk_rope=cfg.mla.qk_rope,
                v_head=cfg.mla.v_head, sin=sin, cos=cos, serve=serve, impl=impl)
        else:
            o, (c1, c2) = attn.gqa_decode(
                lp["attn"], h, (c1, c2), length, policy,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
                sin=sin, cos=cos, serve=serve, impl=impl)
        x = x + o
        h = napply(lp["ln2"], x)
        x = x + _apply_mlp(cfg, lp, h, policy, serve, impl, dense_mlp)
        return x, c1, c2

    c1_all, c2_all = cache
    nd = cfg.dense_first_n
    x_new_caches = []
    for i in range(nd):
        x, c1_i, c2_i = one_layer(x, params[f"dense_layer_{i}"],
                                  c1_all[i], c2_all[i], dense_mlp=True)
        x_new_caches.append((c1_i, c2_i))

    def body(carry, xs):
        lp, c1, c2 = xs
        y, c1, c2 = one_layer(carry, lp, c1, c2)
        return y, (c1, c2)

    x, (c1_s, c2_s) = jax.lax.scan(body, x, (params["layers"],
                                             c1_all[nd:], c2_all[nd:]),
                                   unroll=True if cfg.scan_unroll else 1)
    if nd:
        c1_pre = jnp.stack([c[0] for c in x_new_caches])
        c2_pre = jnp.stack([c[1] for c in x_new_caches])
        c1_s = jnp.concatenate([c1_pre, c1_s], axis=0)
        c2_s = jnp.concatenate([c2_pre, c2_s], axis=0)
    logits = _head(cfg, params, x, policy, serve, impl)
    return logits[:, 0, :], (c1_s, c2_s)


# --------------------------------------------------------------------------
# Workload descriptions (DSE, roofline)
# --------------------------------------------------------------------------


def _per_layer_gemms(cfg: TransformerConfig, tokens: int):
    """GEMMs of one decoder layer at `tokens` activations rows."""
    d, hd = cfg.d_model, cfg.hd
    out = []
    if cfg.mla is not None:
        m = cfg.mla
        out += [
            Gemm("q", tokens, d, cfg.n_heads * (m.qk_nope + m.qk_rope)),
            Gemm("dkv", tokens, d, m.kv_lora + m.qk_rope),
            Gemm("uk", tokens, m.kv_lora, cfg.n_heads * m.qk_nope),
            Gemm("uv", tokens, m.kv_lora, cfg.n_heads * m.v_head),
            Gemm("o", tokens, cfg.n_heads * m.v_head, d),
        ]
    else:
        out += [
            Gemm("q", tokens, d, cfg.n_heads * hd),
            Gemm("k", tokens, d, cfg.n_kv * hd),
            Gemm("v", tokens, d, cfg.n_kv * hd),
            Gemm("o", tokens, cfg.n_heads * hd, d),
        ]
    if cfg.moe is not None:
        mc = cfg.moe
        act_tokens = tokens * mc.topk  # tokens routed through experts
        n_mats = 3 if cfg.act == "swiglu" else 2
        out += [Gemm("expert", act_tokens, d, mc.d_ff, count=n_mats)]
        if mc.n_shared:
            out += [Gemm("shared", tokens, d, mc.shared_hidden, count=n_mats)]
    else:
        n_mats = 3 if cfg.act == "swiglu" else 2
        out += [Gemm("mlp", tokens, d, cfg.d_ff, count=n_mats)]
    return out


def gemm_workload(cfg: TransformerConfig, tokens: int):
    """All GEMMs of one full forward over `tokens` tokens (DSE input)."""
    gemms = []
    for g in _per_layer_gemms(cfg, tokens):
        gemms.append(dataclasses.replace(g, count=g.count * cfg.n_layers))
    gemms.append(Gemm("head", tokens, cfg.d_model, cfg.vocab,
                      layer_class="boundary"))
    return gemms


def active_params(cfg: TransformerConfig) -> int:
    """N_active: params touched per token (MoE counts topk+shared only)."""
    n = 0
    for g in _per_layer_gemms(cfg, 1):
        per = g.k * g.n * g.count
        if g.name == "expert":
            per = cfg.moe.topk * cfg.d_model * cfg.moe.d_ff * \
                (3 if cfg.act == "swiglu" else 2)
        n += per
    n *= cfg.n_layers
    n += 2 * cfg.vocab * cfg.d_model  # embed + head
    return n


def total_params(cfg: TransformerConfig) -> int:
    n = 0
    for g in _per_layer_gemms(cfg, 1):
        per = g.k * g.n * g.count
        if g.name == "expert":
            per = cfg.moe.n_experts * cfg.d_model * cfg.moe.d_ff * \
                (3 if cfg.act == "swiglu" else 2)
        n += per
    n *= cfg.n_layers
    n += 2 * cfg.vocab * cfg.d_model
    return n


def model_flops(cfg: TransformerConfig, *, tokens: int, step: str) -> float:
    """MODEL_FLOPS per step: 6·N_active·tokens (train), 2·N_active·tokens
    (prefill/decode) — the §Roofline 'useful flops' numerator."""
    n_active = active_params(cfg)
    mult = 6.0 if step == "train" else 2.0
    return mult * n_active * tokens
