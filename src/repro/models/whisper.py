"""Whisper-style encoder-decoder (audio backbone only).

Per the assignment the conv/mel frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings (B, n_audio, d_model).  The
transformer backbone (bidirectional encoder, causal decoder with
cross-attention) is fully implemented; positions are sinusoidal.

Decode shapes run the decoder step: growing self-attention cache +
static cross-attention K/V computed once from the encoder output.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.dse import Gemm
from repro.core.precision import PrecisionPolicy
from repro.nn import attention as attn
from repro.nn import layers as nnl
from repro.nn import quantized as Q
from repro.nn.param import ParamSpec
from repro.nn.partitioning import constrain

__all__ = ["WhisperConfig", "specs", "forward", "prefill", "decode_step",
           "cache_specs", "gemm_workload", "model_flops"]


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str
    n_layers: int            # per side (encoder and decoder)
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_audio: int = 1500
    scan_layers: bool = True
    scan_unroll: bool = False
    remat: bool = True
    attn_chunk: int = 512
    family: str = "audio"

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads


def _stack(spec, lead, lead_axes):
    return {k: (ParamSpec(shape=lead + v.shape, dtype=v.dtype,
                          axes=lead_axes + v.axes, init=v.init, const=v.const)
                if isinstance(v, ParamSpec) else _stack(v, lead, lead_axes))
            for k, v in spec.items()}


# gemm_workload name maps: whisper's workload aggregates q/k/v/o into one
# entry per attention kind, and cross-attention splits by operand rows
# (q/o run over tokens -> dec_cross_q; k/v over frames -> dec_cross_kv).
_ENC_ATTN = {k: "enc_qkvo" for k in ("q", "k", "v", "o")}
_DEC_ATTN = {k: "dec_self_qkvo" for k in ("q", "k", "v", "o")}
_X_ATTN = {"q": "dec_cross_q", "o": "dec_cross_q",
           "k": "dec_cross_kv", "v": "dec_cross_kv"}


def _mlp_spec(cfg, *, lead, lead_axes, serve, policy, name):
    mk = functools.partial(Q.qlinear_serve_spec if serve else Q.qlinear_spec,
                           lead=lead, lead_axes=lead_axes, name=name)
    kw = {"policy": policy} if serve else {}
    return {
        "up": mk(cfg.d_model, cfg.d_ff, axes=("embed", "mlp"), **kw),
        "down": mk(cfg.d_ff, cfg.d_model, axes=("mlp", "act_embed"), **kw),
    }


def _enc_layer(cfg, lead, lead_axes, serve, policy, *, attn_names=_ENC_ATTN,
               mlp_name="enc_mlp"):
    return {
        "ln1": _stack(nnl.layernorm_spec(cfg.d_model), lead, lead_axes),
        "attn": attn.gqa_spec(cfg.d_model, cfg.n_heads, cfg.n_heads, cfg.hd,
                              lead=lead, lead_axes=lead_axes, serve=serve,
                              policy=policy, names=attn_names),
        "ln2": _stack(nnl.layernorm_spec(cfg.d_model), lead, lead_axes),
        "mlp": _mlp_spec(cfg, lead=lead, lead_axes=lead_axes, serve=serve,
                         policy=policy, name=mlp_name),
    }


def _dec_layer(cfg, lead, lead_axes, serve, policy):
    return {
        **_enc_layer(cfg, lead, lead_axes, serve, policy,
                     attn_names=_DEC_ATTN, mlp_name="dec_mlp"),
        "ln_x": _stack(nnl.layernorm_spec(cfg.d_model), lead, lead_axes),
        "xattn": attn.gqa_spec(cfg.d_model, cfg.n_heads, cfg.n_heads, cfg.hd,
                               lead=lead, lead_axes=lead_axes, serve=serve,
                               policy=policy, names=_X_ATTN),
    }


def specs(cfg: WhisperConfig, mode: str = "train",
          policy: PrecisionPolicy = PrecisionPolicy()) -> Dict:
    serve = mode == "serve"
    lead, lx = ((cfg.n_layers,), ("layers",)) if cfg.scan_layers else ((), ())
    return {
        "embed": (nnl.embed_serve_spec(nnl.pad_vocab(cfg.vocab), cfg.d_model, policy)
                  if serve else nnl.embed_spec(nnl.pad_vocab(cfg.vocab), cfg.d_model)),
        "enc_layers": _enc_layer(cfg, lead, lx, serve, policy),
        "enc_norm": nnl.layernorm_spec(cfg.d_model),
        "dec_layers": _dec_layer(cfg, lead, lx, serve, policy),
        "dec_norm": nnl.layernorm_spec(cfg.d_model),
        "head": (Q.qlinear_serve_spec(cfg.d_model, nnl.pad_vocab(cfg.vocab),
                                      axes=("embed", "vocab"),
                                      layer_class="boundary", policy=policy,
                                      name="head")
                 if serve else
                 Q.qlinear_spec(cfg.d_model, nnl.pad_vocab(cfg.vocab), axes=("embed", "vocab"),
                                layer_class="boundary", name="head")),
    }


def _sinusoid(positions: jax.Array, dim: int) -> jax.Array:
    sin, cos = nnl.rotary_cache(positions, dim)
    return jnp.concatenate([sin, cos], axis=-1)


def _qapply(serve, impl):
    return (functools.partial(Q.qlinear_serve_apply, impl=impl)
            if serve else Q.qlinear_apply)


def encode(cfg, params, frames, policy, *, serve, impl):
    """frames (B, T, D) stub embeddings -> encoder output (B, T, D)."""
    b, t, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    x = frames.astype(jnp.bfloat16) + _sinusoid(pos, cfg.d_model).astype(jnp.bfloat16)
    sin, cos = nnl.rotary_cache(pos, cfg.hd)

    def body(carry, lp):
        h = nnl.layernorm_apply(lp["ln1"], carry)
        o, _ = attn.gqa_prefill(lp["attn"], h, policy, n_heads=cfg.n_heads,
                                n_kv=cfg.n_heads, head_dim=cfg.hd,
                                sin=sin, cos=cos, causal=False, rope=False,
                                serve=serve, impl=impl, chunk=cfg.attn_chunk,
                                names=_ENC_ATTN)
        y = carry + o
        h = nnl.layernorm_apply(lp["ln2"], y)
        fn = _qapply(serve, impl)
        y = y + fn(lp["mlp"]["down"],
                   nnl.gelu(fn(lp["mlp"]["up"], h, policy, name="enc_mlp")),
                   policy, name="enc_mlp")
        return constrain(y, ("batch", "frames", "act_embed")), None

    fn_ = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn_, x, params["enc_layers"],
                        unroll=True if cfg.scan_unroll else 1)
    return nnl.layernorm_apply(params["enc_norm"], x)


def _dec_layer_fwd(cfg, lp, x, enc_out, policy, sin, cos, serve, impl):
    fn = _qapply(serve, impl)
    h = nnl.layernorm_apply(lp["ln1"], x)
    o, kv = attn.gqa_prefill(lp["attn"], h, policy, n_heads=cfg.n_heads,
                             n_kv=cfg.n_heads, head_dim=cfg.hd,
                             sin=sin, cos=cos, causal=True, rope=False,
                             serve=serve, impl=impl, chunk=cfg.attn_chunk,
                             names=_DEC_ATTN)
    x = x + o
    # cross attention: KV from encoder output
    b, t, _ = enc_out.shape
    h = nnl.layernorm_apply(lp["ln_x"], x)
    q = fn(lp["xattn"]["q"], h, policy,
           name=_X_ATTN["q"]).reshape(*h.shape[:2], cfg.n_heads, cfg.hd)
    k = fn(lp["xattn"]["k"], enc_out, policy,
           name=_X_ATTN["k"]).reshape(b, t, cfg.n_heads, cfg.hd)
    v = fn(lp["xattn"]["v"], enc_out, policy,
           name=_X_ATTN["v"]).reshape(b, t, cfg.n_heads, cfg.hd)
    o = attn.chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    x = x + fn(lp["xattn"]["o"], o.reshape(*h.shape[:2], -1), policy,
               name=_X_ATTN["o"])
    h = nnl.layernorm_apply(lp["ln2"], x)
    x = x + fn(lp["mlp"]["down"],
               nnl.gelu(fn(lp["mlp"]["up"], h, policy, name="dec_mlp")),
               policy, name="dec_mlp")
    return constrain(x, ("batch", "seq", "act_embed")), (kv, (k, v))


def forward(cfg, params, tokens, policy, *, frames=None, mode="train",
            impl="xla"):
    """Teacher-forced decoder logits given audio frames."""
    serve = mode == "serve"
    b, s = tokens.shape
    if frames is None:
        frames = jnp.zeros((b, cfg.n_audio, cfg.d_model), jnp.bfloat16)
    enc_out = encode(cfg, params, frames, policy, serve=serve, impl=impl)
    x = (nnl.embed_serve_apply if serve else nnl.embed_apply)(
        params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    sin, cos = nnl.rotary_cache(pos, cfg.hd)

    def body(carry, lp):
        y, _ = _dec_layer_fwd(cfg, lp, carry, enc_out, policy, sin, cos,
                              serve, impl)
        return y, None

    fn_ = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn_, x, params["dec_layers"],
                        unroll=True if cfg.scan_unroll else 1)
    x = nnl.layernorm_apply(params["dec_norm"], x)
    fn = _qapply(serve, impl)
    logits = fn(params["head"], x, policy, layer_class="boundary",
                name="head")
    return logits[..., :cfg.vocab]  # drop TP vocab padding


def prefill(cfg, params, tokens, policy, *, frames=None, impl="xla",
            mode="serve"):
    serve = mode == "serve"
    b, s = tokens.shape
    if frames is None:
        frames = jnp.zeros((b, cfg.n_audio, cfg.d_model), jnp.bfloat16)
    enc_out = encode(cfg, params, frames, policy, serve=serve, impl=impl)
    x = (nnl.embed_serve_apply if serve else nnl.embed_apply)(
        params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    sin, cos = nnl.rotary_cache(pos, cfg.hd)

    def body(carry, lp):
        y, caches = _dec_layer_fwd(cfg, lp, carry, enc_out, policy, sin, cos,
                                   serve, impl)
        return y, caches

    x, (self_kv, cross_kv) = jax.lax.scan(body, x, params["dec_layers"],
                                          unroll=True if cfg.scan_unroll else 1)
    x = nnl.layernorm_apply(params["dec_norm"], x)
    fn = _qapply(serve, impl)
    logits = fn(params["head"], x[:, -1:, :], policy, layer_class="boundary",
                name="head")
    return logits[:, 0, :cfg.vocab], {"self": self_kv, "cross": cross_kv}


def cache_specs(cfg: WhisperConfig, batch: int, max_len: int):
    l, h, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    kv = lambda s: jax.ShapeDtypeStruct((l, batch, s, h, hd), jnp.bfloat16)
    return {"self": (kv(max_len), kv(max_len)),
            "cross": (kv(cfg.n_audio), kv(cfg.n_audio))}


def cache_axes(cfg: WhisperConfig):
    ax = ("layers", "batch", "kv_seq", "heads", "head_dim")
    return {"self": (ax, ax), "cross": (ax, ax)}


def decode_step(cfg, params, cache, tokens, length, policy, *,
                impl="xla", mode="serve"):
    serve = mode == "serve"
    b = tokens.shape[0]
    x = (nnl.embed_serve_apply if serve else nnl.embed_apply)(
        params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.reshape(length, (1, 1)), (b, 1))
    x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    sin, cos = nnl.rotary_cache(pos, cfg.hd)
    fn = _qapply(serve, impl)

    def body(carry, xs):
        lp, sk, sv, ck, cv = xs
        h = nnl.layernorm_apply(lp["ln1"], carry)
        o, (sk, sv) = attn.gqa_decode(lp["attn"], h, (sk, sv), length, policy,
                                      n_heads=cfg.n_heads, n_kv=cfg.n_heads,
                                      head_dim=cfg.hd, sin=sin, cos=cos,
                                      rope=False, serve=serve, impl=impl,
                                      names=_DEC_ATTN)
        y = carry + o
        h = nnl.layernorm_apply(lp["ln_x"], y)
        q = fn(lp["xattn"]["q"], h, policy,
               name=_X_ATTN["q"]).reshape(b, 1, cfg.n_heads, cfg.hd)
        o = attn.decode_attention(q, ck, cv, jnp.asarray(cfg.n_audio))
        y = y + fn(lp["xattn"]["o"], o.reshape(b, 1, -1), policy,
                   name=_X_ATTN["o"])
        h = nnl.layernorm_apply(lp["ln2"], y)
        y = y + fn(lp["mlp"]["down"],
                   nnl.gelu(fn(lp["mlp"]["up"], h, policy, name="dec_mlp")),
                   policy, name="dec_mlp")
        return y, (sk, sv)

    sk, sv = cache["self"]
    ck, cv = cache["cross"]
    x, (sk, sv) = jax.lax.scan(body, x, (params["dec_layers"], sk, sv, ck, cv),
                               unroll=True if cfg.scan_unroll else 1)
    x = nnl.layernorm_apply(params["dec_norm"], x)
    logits = fn(params["head"], x, policy, layer_class="boundary",
                name="head")
    return logits[:, 0, :cfg.vocab], {"self": (sk, sv), "cross": (ck, cv)}


def gemm_workload(cfg: WhisperConfig, tokens: int, frames: int = None):
    frames = frames or cfg.n_audio
    d, hd, h = cfg.d_model, cfg.hd, cfg.n_heads
    l = cfg.n_layers
    return [
        Gemm("enc_qkvo", frames, d, h * hd, count=4 * l),
        Gemm("enc_mlp", frames, d, cfg.d_ff, count=2 * l),
        Gemm("dec_self_qkvo", tokens, d, h * hd, count=4 * l),
        Gemm("dec_cross_q", tokens, d, h * hd, count=2 * l),
        Gemm("dec_cross_kv", frames, d, h * hd, count=2 * l),
        Gemm("dec_mlp", tokens, d, cfg.d_ff, count=2 * l),
        Gemm("head", tokens, d, cfg.vocab, layer_class="boundary"),
    ]


def active_params(cfg: WhisperConfig) -> int:
    d, hd, h, l = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_layers
    enc = l * (4 * d * h * hd + 2 * d * cfg.d_ff)
    dec = l * (8 * d * h * hd + 2 * d * cfg.d_ff)
    return enc + dec + 2 * cfg.vocab * d


total_params = active_params


def model_flops(cfg, *, tokens: int, step: str) -> float:
    mult = 6.0 if step == "train" else 2.0
    return mult * active_params(cfg) * tokens
